"""Local SpMV/SpMM kernel benchmark against a *measured* ERT-style roofline.

Instead of quoting documented v5e peaks, :func:`repro.launch.roofline.ert_sweep`
measures what this backend actually achieves — streaming bandwidth, random-
gather bandwidth (the ELL kernels' access pattern) and dense FLOP rate —
over several working-set sizes and FLOP intensities.  Each local kernel row
then reports its achieved bytes/s as ``pct_peak`` of the relevant measured
ceiling, plus a ``parity`` field (max relative error vs the host CSR
matvec) the CI gate vets.

Bytes are counted with the *minimal-traffic* model — the sparse operator
read once per apply (cols + vals), one gathered source element per stored
nonzero per RHS, one result write — so the vmapped multi-RHS row, which
really re-reads the operator k times, shows honestly lower ``pct_peak``
than the native SpMM reading it once.

Emits the ``name,us_per_call,derived`` rows used by :mod:`benchmarks.run`,
and — when run standalone — a ``BENCH_kernels.json`` baseline:

    PYTHONPATH=src python -m benchmarks.kernels [--smoke] [--out PATH]
"""
from __future__ import annotations

import json
import os
import time

K_RHS = 8          # multi-RHS batch width the SpMM rows use


def _csr_to_ell(A):
    import numpy as np
    K = int(np.diff(A.indptr).max(initial=1)) or 1
    cols = np.full((A.nrows, K), -1, dtype=np.int32)
    vals = np.zeros((A.nrows, K))
    if A.nnz:
        lens = np.diff(A.indptr)
        r = A.rows_expanded()
        slot = np.arange(A.nnz, dtype=np.int64) - np.repeat(A.indptr[:-1],
                                                            lens)
        cols[r, slot] = A.indices
        vals[r, slot] = A.data
    return cols, vals


def _time_loop(fn, args, reps: int) -> float:
    """Best-of-``reps`` seconds per call (one warm-up call absorbs jit)."""
    import jax
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def rows(smoke: bool | None = None):
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.amg.csr import csr_to_bcsr
    from repro.amg.problems import laplace_3d
    from repro.kernels.spmv.bcsr import bcsr_apply_ref
    from repro.kernels.spmv.ops import select_local_kernel
    from repro.kernels.spmv.ref import ell_spmm_ref, ell_spmv_ref
    from repro.launch.roofline import ert_sweep

    reps = 3 if smoke else 5
    peaks = ert_sweep(smoke=smoke, reps=reps)
    out = []
    t_stream = min(p["seconds"] for p in peaks["points"]
                   if p["kernel"] == "stream")
    t_gather = min(p["seconds"] for p in peaks["points"]
                   if p["kernel"] == "gather")
    common = f"backend={peaks['backend']};smoke={int(peaks['smoke'])}"
    out.append(("ert_stream", t_stream * 1e6,
                f"{common};bw={peaks['stream_bw']:.4g};"
                f"flops_peak={peaks['flops']:.4g};"
                f"documented_bw={peaks['documented_hbm_bw']:.4g}"))
    out.append(("ert_gather", t_gather * 1e6,
                f"{common};bw={peaks['gather_bw']:.4g}"))

    n = 8 if smoke else 14
    A = laplace_3d(n)
    cols_np, vals_np = _csr_to_ell(A)
    nrows, K = cols_np.shape
    rng = np.random.default_rng(0)
    X_np = rng.standard_normal((A.ncols, K_RHS))
    cols = jnp.asarray(cols_np)
    vals = jnp.asarray(vals_np, dtype=jnp.float32)
    x = jnp.asarray(X_np[:, 0], dtype=jnp.float32)
    X = jnp.asarray(X_np, dtype=jnp.float32)
    dsize = x.dtype.itemsize
    # host CSR oracles in fp64 — the parity denominators
    y_ref = A.matvec(np.asarray(x, dtype=np.float64))
    Y_ref = np.stack([A.matvec(np.asarray(X[:, j], dtype=np.float64))
                      for j in range(K_RHS)], axis=1)

    def parity(got, ref):
        got = np.asarray(got, dtype=np.float64)
        denom = np.abs(ref).max() or 1.0
        return np.abs(got - ref).max() / denom

    # minimal-traffic byte models (operator read ONCE per apply)
    a_bytes = nrows * K * (4 + dsize)                    # cols + vals
    spmv_bytes = a_bytes + nrows * K * dsize + nrows * dsize
    spmm_bytes = (a_bytes + nrows * K * K_RHS * dsize
                  + nrows * K_RHS * dsize)

    def kern_row(name, fn, args, byts, ref, extra=""):
        s = _time_loop(fn, args, reps)
        bw = byts / s
        pct = 100.0 * bw / peaks["gather_bw"]
        got = fn(*args)
        return (name, s * 1e6,
                f"impl=jnp_inline;n={nrows};K={K};bytes={byts:.4g};"
                f"achieved_bw={bw:.4g};pct_peak={pct:.2f};"
                f"parity={parity(got, ref):.3e}{extra}")

    out.append(kern_row("kern_ell_spmv", jax.jit(ell_spmv_ref),
                        (cols, vals, x), spmv_bytes, y_ref))
    out.append(kern_row(f"kern_ell_spmm_k{K_RHS}", jax.jit(ell_spmm_ref),
                        (cols, vals, X), spmm_bytes, Y_ref,
                        extra=f";k={K_RHS}"))
    vmapped = jax.jit(jax.vmap(ell_spmv_ref, in_axes=(None, None, 1),
                               out_axes=1))
    out.append(kern_row(f"kern_ell_vmap_k{K_RHS}", vmapped,
                        (cols, vals, X), spmm_bytes, Y_ref,
                        extra=f";k={K_RHS}"))
    sel = select_local_kernel(cols_np)
    bs = sel["block_size"] or 8
    B = csr_to_bcsr(A, bs)
    bcols = jnp.asarray(B.bcols)
    bvals = jnp.asarray(B.bvals, dtype=jnp.float32)
    bcsr_fn = jax.jit(
        lambda bc, bv, xx: bcsr_apply_ref(bc, bv, xx)[: nrows])
    mb, Kb = B.bcols.shape
    bcsr_bytes = (mb * Kb * 4 + mb * Kb * bs * bs * dsize
                  + mb * Kb * bs * K_RHS * dsize + mb * bs * K_RHS * dsize)
    out.append(kern_row(f"kern_bcsr_spmm_k{K_RHS}", bcsr_fn,
                        (bcols, bvals, X), bcsr_bytes, Y_ref,
                        extra=(f";k={K_RHS};bs={bs};"
                               f"heuristic={sel['kernel']};"
                               f"bcsr_fill={sel['bcsr_fill']:.3f}")))
    return out


def main(argv=None) -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true")
    parser.add_argument("--out", default="BENCH_kernels.json")
    args = parser.parse_args(argv)
    data = rows(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in data:
        print(f"{name},{us:.2f},{derived}")
    with open(args.out, "w") as f:
        json.dump({"benchmark": "kernels",
                   "rows": [{"name": n, "us_per_call": u, "derived": d}
                            for n, u, d in data]}, f, indent=2)
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
