"""Fig. 21: node-aware speedup of the Galerkin Pᵀ·(AP) communication for a
2D rotated anisotropic diffusion system, with 1 vs 2 Jacobi prolongation-
smoothing sweeps.  Denser P (2 sweeps) → more matrix comm → larger NAP wins."""
from repro.amg import setup
from repro.amg.dist import matrix_comm_graph, row_partition
from repro.amg.problems import rotated_anisotropic_2d
from repro.core import BLUE_WATERS, Partition, Topology, build
from repro.core.perf_model import model_time


def rows(n=48, n_nodes=16, ppn=16):
    A = rotated_anisotropic_2d(n)
    topo = Topology(n_nodes=n_nodes, ppn=ppn)
    out = []
    for sweeps in (1, 2):
        h = setup(A, solver="sa", prolongation_sweeps=sweeps)
        for l, lv in enumerate(h.levels):
            if lv.AP is None:
                continue
            cpart = Partition.balanced(lv.P.ncols, topo)
            rpart = row_partition(lv.A, topo)
            g = matrix_comm_graph(lv.R, lv.AP, cpart, b_part=rpart)
            times = {s: model_time(build(s, g), BLUE_WATERS)
                     for s in ("standard", "nap2", "nap3")}
            best = min(times.values())
            speed = times["standard"] / best if best > 0 else 1.0
            out.append((f"fig21_PtAP_sweeps{sweeps}_L{l}", best * 1e6,
                        f"speedup={speed:.2f}x;"
                        f"P_nnz_row={lv.P.nnz / max(lv.P.nrows, 1):.1f}"))
    return out
