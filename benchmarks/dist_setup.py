"""Setup-phase benchmark: the partitioned node-aware Galerkin products
(paper Figs. 14/15's setup-phase claim, executed rather than simulated).

For ≥3 problem sizes: host ``hierarchy.setup`` vs partitioned
``dist_setup_partitioned`` wall time, plus one row per (level, SpGEMM op)
with the model-selected strategy, its modeled microseconds per strategy,
and the *measured* exchange (inter/intra messages, bytes, seconds) — the
modeled-vs-measured comparison the selection relies on.

Emits the ``name,us_per_call,derived`` rows used by :mod:`benchmarks.run`,
and — when run standalone — a ``BENCH_dist_setup.json`` record file:

    PYTHONPATH=src python -m benchmarks.dist_setup [--smoke] [--out PATH]

``--smoke`` (or ``REPRO_BENCH_SMOKE=1``) shrinks the sizes so the whole
benchmark runs in seconds.  The partitioned setup loop is numpy-only (it
models the mesh with a Topology), so no multi-device XLA platform is
needed — this runs anywhere the tier-1 tests run.
"""
from __future__ import annotations

import json
import os
import time

MESH = (2, 4)


def rows(smoke: bool | None = None):
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    import numpy as np  # noqa: F401

    from repro.amg import setup
    from repro.amg.dist_setup import dist_setup_partitioned
    from repro.amg.problems import laplace_3d
    from repro.core import BLUE_WATERS

    sizes = (6, 8, 10) if smoke else (12, 16, 20)
    n_pods, lanes = MESH
    out = []
    for n in sizes:
        A = laplace_3d(n)
        t0 = time.perf_counter()
        h = setup(A, solver="rs")
        host_dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        plv, recs = dist_setup_partitioned(A, n_pods, lanes,
                                           params=BLUE_WATERS)
        dist_dt = time.perf_counter() - t0
        assert len(plv) == h.n_levels, "partitioned setup level mismatch"
        out.append((f"host_setup_n{A.nrows}", host_dt * 1e6,
                    f"levels={h.n_levels};nnz={A.nnz}"))
        out.append((f"dist_setup_n{A.nrows}", dist_dt * 1e6,
                    f"mesh={n_pods}x{lanes};levels={len(plv)};"
                    f"dist_vs_host={dist_dt / max(host_dt, 1e-12):.2f}x"))
        # per-level modeled-vs-measured strategy rows (the paper's setup
        # phase = the two Galerkin SpGEMM row exchanges per level)
        for r in recs:
            modeled = ";".join(f"{s}={t * 1e6:.1f}" for s, t in
                               sorted(r.modeled.items()))
            out.append((
                f"dist_setup_n{A.nrows}_L{r.level}_{r.op}",
                r.seconds * 1e6,
                f"strategy={r.strategy};modeled_us={modeled};"
                f"inter_msgs={r.inter_msgs};inter_bytes={r.inter_bytes:.0f};"
                f"intra_msgs={r.intra_msgs};halo_rows={r.n_halo_rows}"))
    return out


def main(argv=None) -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true")
    parser.add_argument("--out", default="BENCH_dist_setup.json")
    args = parser.parse_args(argv)
    data = rows(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in data:
        print(f"{name},{us:.2f},{derived}")
    with open(args.out, "w") as f:
        json.dump({"benchmark": "dist_setup",
                   "rows": [{"name": n, "us_per_call": u, "derived": d}
                            for n, u, d in data]}, f, indent=2)
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
