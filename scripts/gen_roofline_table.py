"""Generate the §Dry-run and §Roofline markdown tables from
dryrun_results.json (paste into EXPERIMENTS.md)."""
import json
import sys

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    if b is None:
        return "n/a"
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if b >= div:
            return f"{b / div:.2f} {unit}"
    return f"{b:.0f} B"


def main(path="dryrun_results.json"):
    rs = json.load(open(path))
    cells = {}
    skips = {}
    for r in rs:
        if r.get("skipped"):
            skips[(r["arch"], r["shape"])] = r["skipped"]
            continue
        if str(r.get("arch", "")).startswith("amg_spmv"):
            continue
        cells[(r["arch"], r["shape"], r["mesh"])] = r

    print("### §Dry-run (lower+compile per cell; peak bytes/device from "
          "memory_analysis)\n")
    print("| arch | shape | mesh | compile s | peak/dev | collectives | "
          "cross-pod bytes/dev |")
    print("|---|---|---|---|---|---|---|")
    archs = sorted({k[0] for k in cells} | {k[0] for k in skips})
    for a in archs:
        for s in ORDER:
            if (a, s) in skips:
                print(f"| {a} | {s} | — | — | — | — | SKIPPED "
                      f"({skips[(a, s)]}) |")
                continue
            for mesh in ("16x16", "2x16x16"):
                r = cells.get((a, s, mesh))
                if not r:
                    continue
                if "error" in r:
                    print(f"| {a} | {s} | {mesh} | ERROR {r['error'][:50]} |")
                    continue
                peak = r.get("memory_analysis", {}).get("peak_per_device")
                print(f"| {a} | {s} | {mesh} | {r['compile_s']:.0f} | "
                      f"{fmt_bytes(peak)} | {r.get('n_collectives', 0):.0f} | "
                      f"{fmt_bytes(r.get('cross_pod_bytes_per_dev'))} |")

    print("\n### §Roofline (terms in seconds/step; single-pod 16x16)\n")
    print("| arch | shape | compute | memory (HLO) | memory floor | "
          "collective | dominant | MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in archs:
        for s in ORDER:
            r = cells.get((a, s, "16x16"))
            if not r or "error" in r:
                continue
            print(f"| {a} | {s} | {r['compute_s']:.3f} | "
                  f"{r['memory_s']:.3f} | {r.get('memory_floor_s', 0):.3f} | "
                  f"{r['collective_s']:.3f} | {r['dominant']} | "
                  f"{r['useful_flops_fraction']:.2f} | "
                  f"{r['roofline_fraction']:.4f} |")

    print("\n### multi-pod (2x16x16) cross-pod view\n")
    print("| arch | shape | cross-pod bytes/dev | cross-pod s | dominant |")
    print("|---|---|---|---|---|")
    for a in archs:
        for s in ORDER:
            r = cells.get((a, s, "2x16x16"))
            if not r or "error" in r:
                continue
            print(f"| {a} | {s} | {fmt_bytes(r['cross_pod_bytes_per_dev'])} | "
                  f"{r['cross_pod_s']:.3f} | {r['dominant']} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
