"""Generate the §Dry-run and §Roofline markdown tables from
dryrun_results.json (paste into EXPERIMENTS.md), plus the measured-peak
table from BENCH_kernels.json: peaks there come from the ERT sweep
(:func:`repro.launch.roofline.ert_sweep`), so the per-kernel columns are
"% of what this machine measured", not documented-estimate fractions."""
import json
import os
import re
import sys

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
BENCH_KERNELS = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_kernels.json")


def _derived_map(derived):
    return dict(re.findall(r"([A-Za-z_][A-Za-z0-9_]*)=([^;]+)", derived))


def print_measured_table(path=BENCH_KERNELS):
    if not os.path.exists(path):
        print("\n### §Measured roofline — missing (run: python -m "
              "benchmarks.kernels --smoke)\n")
        return
    rows = json.load(open(path))["rows"]
    print("\n### §Measured roofline (ERT sweep — empirical peaks, "
          "not documented constants)\n")
    print("| micro-kernel | best µs | measured peak B/s | "
          "documented B/s |")
    print("|---|---|---|---|")
    for r in rows:
        if not r["name"].startswith("ert_"):
            continue
        d = _derived_map(r["derived"])
        print(f"| {r['name']} | {r['us_per_call']:.1f} | "
              f"{d.get('bw', 'n/a')} | {d.get('documented_bw', '—')} |")
    print("\n| kernel | µs/call | achieved B/s | % of measured peak | "
          "parity vs host CSR |")
    print("|---|---|---|---|---|")
    for r in rows:
        if not r["name"].startswith("kern_"):
            continue
        d = _derived_map(r["derived"])
        print(f"| {r['name']} | {r['us_per_call']:.1f} | "
              f"{d.get('achieved_bw', 'n/a')} | {d.get('pct_peak', 'n/a')}% "
              f"| {d.get('parity', 'n/a')} |")


def fmt_bytes(b):
    if b is None:
        return "n/a"
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if b >= div:
            return f"{b / div:.2f} {unit}"
    return f"{b:.0f} B"


def main(path="dryrun_results.json", bench_kernels=BENCH_KERNELS):
    if not os.path.exists(path):
        print(f"(no {path} — dry-run tables skipped)")
        print_measured_table(bench_kernels)
        return
    rs = json.load(open(path))
    cells = {}
    skips = {}
    for r in rs:
        if r.get("skipped"):
            skips[(r["arch"], r["shape"])] = r["skipped"]
            continue
        if str(r.get("arch", "")).startswith("amg_spmv"):
            continue
        cells[(r["arch"], r["shape"], r["mesh"])] = r

    print("### §Dry-run (lower+compile per cell; peak bytes/device from "
          "memory_analysis)\n")
    print("| arch | shape | mesh | compile s | peak/dev | collectives | "
          "cross-pod bytes/dev |")
    print("|---|---|---|---|---|---|---|")
    archs = sorted({k[0] for k in cells} | {k[0] for k in skips})
    for a in archs:
        for s in ORDER:
            if (a, s) in skips:
                print(f"| {a} | {s} | — | — | — | — | SKIPPED "
                      f"({skips[(a, s)]}) |")
                continue
            for mesh in ("16x16", "2x16x16"):
                r = cells.get((a, s, mesh))
                if not r:
                    continue
                if "error" in r:
                    print(f"| {a} | {s} | {mesh} | ERROR {r['error'][:50]} |")
                    continue
                peak = r.get("memory_analysis", {}).get("peak_per_device")
                print(f"| {a} | {s} | {mesh} | {r['compile_s']:.0f} | "
                      f"{fmt_bytes(peak)} | {r.get('n_collectives', 0):.0f} | "
                      f"{fmt_bytes(r.get('cross_pod_bytes_per_dev'))} |")

    print("\n### §Roofline (terms in seconds/step; single-pod 16x16)\n")
    print("| arch | shape | compute | memory (HLO) | memory floor | "
          "collective | dominant | MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in archs:
        for s in ORDER:
            r = cells.get((a, s, "16x16"))
            if not r or "error" in r:
                continue
            print(f"| {a} | {s} | {r['compute_s']:.3f} | "
                  f"{r['memory_s']:.3f} | {r.get('memory_floor_s', 0):.3f} | "
                  f"{r['collective_s']:.3f} | {r['dominant']} | "
                  f"{r['useful_flops_fraction']:.2f} | "
                  f"{r['roofline_fraction']:.4f} |")

    print("\n### multi-pod (2x16x16) cross-pod view\n")
    print("| arch | shape | cross-pod bytes/dev | cross-pod s | dominant |")
    print("|---|---|---|---|---|")
    for a in archs:
        for s in ORDER:
            r = cells.get((a, s, "2x16x16"))
            if not r or "error" in r:
                continue
            print(f"| {a} | {s} | {fmt_bytes(r['cross_pod_bytes_per_dev'])} | "
                  f"{r['cross_pod_s']:.3f} | {r['dominant']} |")

    print_measured_table(bench_kernels)


if __name__ == "__main__":
    main(*sys.argv[1:])
